"""Unit tests for the sweep execution planner (:mod:`repro.core.sweep_plan`).

The planner is pure host-side arithmetic, so these tests pin its
invariants directly: record alignment with the measurement grid, exact
pow2 chunk decomposition, memory-capped strides for score-heavy batches,
mesh clamping, and the env overrides the benchmarks/tests rely on.
"""
import numpy as np
import pytest

from repro.core.sweep_plan import parse_mesh, plan_sweep


@pytest.fixture(autouse=True)
def _no_ambient_mesh(monkeypatch):
    """The CI factorization matrix exports PSP_SWEEP_MESH globally; the
    planner tests exercise explicit arguments (and their own env cases),
    so the ambient override must not leak in."""
    monkeypatch.delenv("PSP_SWEEP_MESH", raising=False)


def _measure_idx(n_ticks, every):
    return np.arange(every - 1, n_ticks, every)


class TestStride:
    def test_stride_divides_measurement_cadence(self):
        plan = plan_sweep(1000, _measure_idx(1000, 25), 25, 100,
                          batch=8, d=32, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        assert plan.stride == 25
        # every measurement index lands exactly on a record boundary
        for m in _measure_idx(1000, 25):
            assert (m + 1) % plan.stride == 0

    def test_full_grid_lands_on_a_record(self):
        # 130 ticks, measurements every 25: gcd(25, 130) = 5
        plan = plan_sweep(130, _measure_idx(130, 25), 4, 16,
                          batch=4, d=8, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        assert plan.stride == 5
        assert plan.n_rec_live * plan.stride >= 130

    def test_masked_scores_cap_the_stride(self):
        # B·P² per-row score matrices: a large churn batch must pick a
        # smaller stride than the no-churn fast path would
        fast = plan_sweep(4096, _measure_idx(4096, 64), 64, 256,
                          batch=8, d=32, k_max=4, masked=False,
                          has_churn=False, n_devices=1)
        heavy = plan_sweep(4096, _measure_idx(4096, 64), 64, 256,
                           batch=8, d=32, k_max=4, masked=True,
                           has_churn=True, n_devices=1)
        assert heavy.stride < fast.stride
        assert fast.stride % heavy.stride == 0   # still cadence-aligned

    def test_env_override_snaps_to_divisor(self, monkeypatch):
        monkeypatch.setenv("PSP_TRACE_STRIDE", "10")
        plan = plan_sweep(1000, _measure_idx(1000, 25), 25, 100,
                          batch=8, d=32, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        # 10 does not divide 25; the nearest admissible divisor is 5
        assert plan.stride == 5


class TestChunks:
    def test_binary_decomposition_is_exact_largest_first(self):
        plan = plan_sweep(1000, _measure_idx(1000, 25), 25, 100,
                          batch=8, d=32, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        assert plan.chunks == (32, 8)
        assert sum(plan.chunks) == plan.n_rec == plan.n_rec_live
        assert list(plan.chunks) == sorted(plan.chunks, reverse=True)
        assert all(c & (c - 1) == 0 for c in plan.chunks)   # pow2

    def test_forced_uniform_chunks_cover_live_records(self, monkeypatch):
        monkeypatch.setenv("PSP_SWEEP_CHUNK", "16")
        plan = plan_sweep(1000, _measure_idx(1000, 25), 25, 100,
                          batch=8, d=32, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        assert plan.chunks == (16, 16, 16)
        assert plan.n_rec >= plan.n_rec_live


class TestMesh:
    def test_clamped_to_rows_and_available_devices(self):
        import jax
        plan = plan_sweep(100, _measure_idx(100, 25), 3, 16,
                          batch=4, d=8, k_max=1, masked=False,
                          has_churn=False, n_devices=64)
        assert plan.n_devices <= min(3, len(jax.devices()))
        assert plan.b_pad % plan.n_devices == 0
        assert plan.node_pad % plan.n_devices == 0
        assert plan.b_pad >= 3
        assert plan.node_pad >= 16

    def test_env_override(self, monkeypatch):
        from repro.kernels.psp_tick import DATA_PLANE_BLOCK
        monkeypatch.setenv("PSP_SWEEP_DEVICES", "1")
        plan = plan_sweep(100, _measure_idx(100, 25), 8, 16,
                          batch=4, d=8, k_max=1, masked=False,
                          has_churn=False)
        assert plan.n_devices == 1
        # rows pad to the data-plane GEMM block width per device
        assert plan.b_pad == DATA_PLANE_BLOCK


class TestParseMesh:
    @pytest.mark.parametrize("spec,want", [
        ("4x2", (4, 2)), ("1x1", (1, 1)), ("8X1", (8, 1)),
        (" 2x4 ", (2, 4)), ("16x16", (16, 16)),
    ])
    def test_accepts_rxn(self, spec, want):
        assert parse_mesh(spec) == want

    @pytest.mark.parametrize("spec", [
        "4x", "x2", "4", "axb", "4x2x1", "-4x2", "0x2", "4x0",
        "4*2", "", "4 x 2",
    ])
    def test_rejects_malformed(self, spec):
        with pytest.raises(ValueError):
            parse_mesh(spec)


class TestMesh2D:
    def _plan(self, B=8, P=16, **kw):
        kw.setdefault("batch", 4)
        kw.setdefault("d", 8)
        kw.setdefault("k_max", 1)
        kw.setdefault("masked", False)
        kw.setdefault("has_churn", False)
        return plan_sweep(100, _measure_idx(100, 25), B, P, **kw)

    def test_explicit_mesh_factorizes_devices(self):
        import jax
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 devices")
        plan = self._plan(mesh=(4, 2))
        assert plan.mesh == (4, 2)
        assert plan.n_devices == 8
        assert plan.rows == 4 and plan.nodes == 2

    def test_node_axis_must_divide_p_exactly(self):
        import jax
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 devices")
        # P = 100: a nodes=8 request degrades to the largest divisor ≤ 8
        plan = self._plan(B=1, P=100, mesh=(1, 8))
        assert plan.nodes == 5
        assert plan.p_loc * plan.nodes == 100

    def test_padding_invariants(self):
        import jax
        from repro.kernels.psp_tick import DATA_PLANE_BLOCK
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 devices")
        for mesh, B, P in [((2, 4), 5, 12), ((4, 2), 7, 16),
                           ((1, 8), 3, 24), ((8, 1), 9, 10)]:
            plan = self._plan(B=B, P=P, mesh=mesh)
            rows, nodes = plan.mesh
            assert P % nodes == 0
            assert plan.p_loc == P // nodes
            # row blocks: GEMM-width padded, equal per rows-axis shard
            assert plan.b_pad % (rows * DATA_PLANE_BLOCK) == 0
            assert plan.b_pad >= B
            # node-keyed draw slots: per node column, padded to the rows
            # axis (each column's draws split over rows and all-gather)
            col = plan.node_pad // nodes
            assert col == -(-plan.p_loc // rows) * rows
            assert plan.node_pad >= P

    def test_degenerate_mesh_equals_1d_plan(self):
        import jax
        ndev = min(4, len(jax.devices()))
        if ndev < 2:
            pytest.skip("needs >1 device")
        one_d = self._plan(n_devices=ndev)
        two_d = self._plan(mesh=(ndev, 1))
        assert two_d.mesh == (ndev, 1)
        assert (two_d.stride, two_d.chunks, two_d.b_pad, two_d.node_pad,
                two_d.n_devices) == \
               (one_d.stride, one_d.chunks, one_d.b_pad, one_d.node_pad,
                one_d.n_devices)

    def test_env_override_and_precedence(self, monkeypatch):
        import jax
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 devices")
        monkeypatch.setenv("PSP_SWEEP_MESH", "2x4")
        plan = self._plan()
        assert plan.mesh == (2, 4)
        # an explicit mesh kwarg beats the env override
        plan = self._plan(mesh=(8, 1))
        assert plan.mesh == (8, 1)
        # malformed env specs fail loudly, not silently
        monkeypatch.setenv("PSP_SWEEP_MESH", "8by1")
        with pytest.raises(ValueError):
            self._plan()

    def test_rows_clamp_to_batch_then_nodes_fit_remaining(self):
        import jax
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 devices")
        # B=2 clamps rows 8→2; nodes budget is avail//rows = 4
        plan = self._plan(B=2, P=16, mesh=(8, 4))
        assert plan.rows == 2
        assert plan.nodes == 4
        assert plan.n_devices <= len(jax.devices())


@pytest.mark.parametrize("B,ndev", [(5, 2), (7, 4), (1, 8)])
def test_row_padding_is_even(B, ndev, monkeypatch):
    import jax
    from repro.kernels.psp_tick import DATA_PLANE_BLOCK
    plan = plan_sweep(100, _measure_idx(100, 25), B, 12,
                      batch=4, d=8, k_max=1, masked=False,
                      has_churn=False, n_devices=ndev)
    eff = min(ndev, B, len(jax.devices()))
    assert plan.n_devices == eff
    # per-device block: ceil(B/eff) rows, rounded up to the GEMM width
    b_rows = -(-B // eff)
    b_loc = -(-b_rows // DATA_PLANE_BLOCK) * DATA_PLANE_BLOCK
    assert plan.b_pad == b_loc * eff
    assert plan.b_pad % (eff * DATA_PLANE_BLOCK) == 0
