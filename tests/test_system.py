"""End-to-end behaviour: the paper's claims on a real (small) model.

Trains a reduced transformer with the PSP trainer under different barriers
on synthetic LM data and checks the paper's headline result: probabilistic
barriers iterate near ASP speed (virtual time) while keeping the model
consistent enough to learn — i.e. pBSP advances more steps per virtual
second than BSP when stragglers are present, and still converges.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.spmd_psp import PSPConfig, psp_init, psp_train_step
from repro.data import SyntheticLM
from repro.models import init_model, loss_fn
from repro.optim import adamw, clip_by_norm

W = 4          # PSP workers
TICKS = 60


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, vocab_size=64, n_layers=2, d_model=128,
                              remat=False)
    data = SyntheticLM(vocab_size=64, seq_len=64, batch=W * 4, seed=0)
    batches = []
    it = iter(data)
    for _ in range(8):
        b = next(it)["tokens"].reshape(W, 4, 64)
        batches.append(b)
    return cfg, batches


def run_barrier(setup, barrier, straggler_frac=0.25, ticks=TICKS):
    cfg, batches = setup
    opt = adamw(2e-3)

    def grad_fn(params, tokens):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, {"tokens": tokens}, cfg)
        return loss, clip_by_norm(g, 1.0)

    pcfg = PSPConfig(barrier=barrier, n_workers=W, sample_size=2,
                     staleness=2, straggler_frac=straggler_frac)
    params = init_model(cfg, jax.random.PRNGKey(0))
    st = psp_init(pcfg, params, opt.init, jax.random.PRNGKey(1))
    step = jax.jit(lambda s, b: psp_train_step(pcfg, grad_fn, opt.update,
                                               s, b))
    for t in range(ticks):
        st, m = step(st, batches[t % len(batches)])
    loss, _ = loss_fn(st.server_params, {"tokens": batches[0][0]}, cfg)
    return float(loss), float(m["virtual_time"]), float(m["mean_step"])


def test_psp_trains_real_model(setup):
    loss, vtime, steps = run_barrier(setup, "pbsp")
    cfg, batches = setup
    init_loss = float(loss_fn(init_model(cfg, jax.random.PRNGKey(0)),
                              {"tokens": batches[0][0]}, cfg)[0])
    assert loss < init_loss - 0.1          # actually learned something
    assert steps > 0 and vtime > 0


def test_pbsp_faster_than_bsp_under_stragglers(setup):
    _, vt_bsp, st_bsp = run_barrier(setup, "bsp")
    _, vt_pbsp, st_pbsp = run_barrier(setup, "pbsp")
    # same tick budget: pBSP advances more steps per virtual second
    assert st_pbsp / vt_pbsp > st_bsp / vt_bsp


def test_all_barriers_finite(setup):
    for b in ("bsp", "ssp", "asp", "pbsp", "pssp"):
        loss, _, _ = run_barrier(setup, b, ticks=20)
        assert np.isfinite(loss), b
