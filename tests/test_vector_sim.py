"""Vectorized sweep engine: equivalence with the event-driven reference,
sweep API semantics, determinism, grouping and fallback behaviour."""
import numpy as np
import pytest

from repro.core.barriers import make_barrier
from repro.core.engines import P2PEngine, ParameterServerEngine
from repro.core.simulator import SimConfig, run_simulation
from repro.core.vector_sim import VectorSimulator, run_sweep

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")


def _cfg(name, **kw):
    defaults = dict(n_nodes=64, duration=10.0, dim=16, seed=7)
    defaults.update(kw)
    return SimConfig(barrier=make_barrier(name, staleness=4, sample_size=2),
                     **defaults)


@pytest.fixture(scope="module")
def matched():
    cfgs = [_cfg(n) for n in FIVE]
    return ([run_simulation(c) for c in cfgs], run_sweep(cfgs))


class TestEquivalence:
    """Distribution-level match on matched seeds (acceptance criterion)."""

    def test_mean_progress_within_tolerance(self, matched):
        event, vector = matched
        for name, e, v in zip(FIVE, event, vector):
            assert abs(v.mean_progress - e.mean_progress) <= \
                0.10 * e.mean_progress + 1.0, (name, e.mean_progress,
                                               v.mean_progress)

    def test_final_error_within_tolerance(self, matched):
        event, vector = matched
        for name, e, v in zip(FIVE, event, vector):
            assert abs(v.final_error - e.final_error) < 0.05, name

    def test_lag_pmf_shape(self, matched):
        """Same qualitative lag structure: tight for (p)BSP, bounded for
        (p)SSP, heavy-tailed for ASP — and close pmf mass on the head."""
        event, vector = matched
        spreads_e = {n: int(r.steps.max() - r.steps.min())
                     for n, r in zip(FIVE, event)}
        spreads_v = {n: int(r.steps.max() - r.steps.min())
                     for n, r in zip(FIVE, vector)}
        for s in (spreads_e, spreads_v):
            assert s["bsp"] <= 1
            assert s["ssp"] <= 5
            assert s["asp"] > s["pssp"] >= s["pbsp"]
        # mean lag within tolerance (the pmf head itself is phase-sensitive
        # at the horizon cutoff for lockstep barriers)
        for name, e, v in zip(FIVE, event, vector):
            lag_e = float((e.steps.max() - e.steps).mean())
            lag_v = float((v.steps.max() - v.steps).mean())
            assert abs(lag_e - lag_v) <= 0.15 * lag_e + 1.0, \
                (name, lag_e, lag_v)

    def test_update_counts_match(self, matched):
        event, vector = matched
        for name, e, v in zip(FIVE, event, vector):
            assert abs(v.total_updates - e.total_updates) <= \
                0.10 * e.total_updates + 16, name


class TestSweepAPI:
    def test_results_in_input_order_across_groups(self):
        # interleave two structural groups; order must be preserved
        cfgs = [_cfg("pbsp", n_nodes=16), _cfg("bsp", n_nodes=32),
                _cfg("asp", n_nodes=16), _cfg("ssp", n_nodes=32)]
        results = run_sweep(cfgs)
        assert [len(r.steps) for r in results] == [16, 32, 16, 32]
        assert all(r.mean_progress > 0 for r in results)

    def test_determinism(self):
        cfgs = [_cfg(n, duration=5.0) for n in FIVE]
        r1, r2 = run_sweep(cfgs), run_sweep(cfgs)
        for a, b in zip(r1, r2):
            assert np.array_equal(a.steps, b.steps)
            assert np.array_equal(a.errors, b.errors)
            assert a.total_updates == b.total_updates

    def test_churn_runs_natively_no_fallback(self):
        # churn rows are a distinct structural group (alive masks + event
        # schedules) but run on the vector engine — no event-sim fallback
        churn = _cfg("pbsp", duration=5.0, churn_leave_rate=0.5,
                     churn_join_rate=0.5)
        direct = VectorSimulator([churn]).run()[0]     # accepted directly
        sweep = run_sweep([_cfg("pbsp", duration=5.0), churn])
        assert all(r.mean_progress > 0 for r in sweep)
        assert all(np.isfinite(r.final_error) for r in sweep)
        # deterministic engine: the sweep's churn row is the direct run
        assert np.array_equal(direct.steps, sweep[1].steps)
        assert direct.total_updates == sweep[1].total_updates

    def test_heterogeneous_batch_rejected_directly(self):
        with pytest.raises(ValueError):
            VectorSimulator([_cfg("bsp", n_nodes=8),
                             _cfg("bsp", n_nodes=16)])

    def test_coarse_grid_rejected(self):
        # dt > poll_interval would silently cap throughput at one
        # step/node/tick and skip poll attempts — must be refused
        cfg = _cfg("pbsp", duration=2.0)
        with pytest.raises(ValueError):
            VectorSimulator([cfg], dt=10 * cfg.poll_interval)
        run_sweep([cfg], dt=0.5 * cfg.poll_interval)   # finer is fine

    def test_trace_grid_matches_event_sim(self):
        cfg = _cfg("asp", duration=5.0)
        v = run_sweep([cfg])[0]
        e = run_simulation(cfg)
        assert np.allclose(v.times, e.times)
        assert v.errors.shape == e.errors.shape
        assert v.server_updates[-1] == v.total_updates

    def test_distributed_sampling_charges_control_plane(self):
        central = run_sweep([_cfg("pssp", duration=5.0)])[0]
        dist = run_sweep([_cfg("pssp", duration=5.0,
                               distributed_sampling=True)])[0]
        assert central.control_messages == 0
        assert dist.control_messages > 0

    def test_lr_stability_default(self):
        # default lr = 0.5/P keeps the quadratic task stable at any P
        for n in (8, 128):
            r = run_sweep([_cfg("asp", n_nodes=n, duration=5.0)])[0]
            assert r.final_error < 1.0


class TestEngineSweep:
    def test_ps_engine_run_sweep(self):
        eng = ParameterServerEngine("pssp")
        res = eng.run_sweep(
            [{"straggler_frac": f} for f in (0.0, 0.1)],
            n_nodes=32, duration=4.0, dim=8)
        assert len(res) == 2
        assert all(r.mean_progress > 0 for r in res)

    def test_engine_sweep_barrier_override(self):
        eng = ParameterServerEngine("pssp")
        res = eng.run_sweep([{"barrier": "bsp"}, {"barrier": "asp"}],
                            n_nodes=32, duration=4.0, dim=8)
        assert int(res[0].steps.max() - res[0].steps.min()) <= 1
        assert res[1].mean_progress > res[0].mean_progress

    def test_engine_sweep_rejects_invalid_combination(self):
        with pytest.raises(ValueError):
            P2PEngine("pbsp").run_sweep([{"barrier": "bsp"}],
                                        n_nodes=16, duration=2.0, dim=8)

    def test_p2p_engine_sweep_pays_hops(self):
        res = P2PEngine("pbsp").run_sweep([{}], n_nodes=32, duration=4.0,
                                          dim=8)
        assert res[0].control_messages > 0
