"""Cross-engine equivalence suite: numpy backend × jax backend × event sim.

Property-based when ``hypothesis`` is installed (scenario matrices of
barrier × straggler × churn × seed; example count tunable via the
``PSP_HYP_EXAMPLES`` env var for the CI fast lane), with a deterministic
pseudo-random scenario matrix as the fallback so the suite always runs.

Also pins per-backend golden traces (tick-ordering drift detector), the
batched-churn native path, sweep output order/shape invariance across
backends and grouping, and the variance-band figure helper.
"""
import dataclasses
import itertools
import json
import os

import numpy as np
import pytest

from repro.core import env
from repro.core.barriers import make_barrier
from repro.core.simulator import SimConfig, run_simulation
from repro.core.vector_sim import VectorSimulator, run_sweep

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")
N_EXAMPLES = env.get_int("PSP_HYP_EXAMPLES")
GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "vector_sim_trace.json")

# per-example seed-averaged tolerances, calibrated on an 80-scenario matrix
# (worst single-seed deviation ≈ 13% no-churn / 27% churn at this scale;
# averaging 3 seeds per example brings it under the bounds below)
_TOL = {False: dict(prog=0.12, err=0.05, upd=0.12, slack=0.5),
        True: dict(prog=0.25, err=0.06, upd=0.25, slack=1.5)}


def _scenario(name: str, frac: float, churn: bool, seed: int) -> SimConfig:
    return SimConfig(n_nodes=24, duration=5.0, dim=8, batch=4, seed=seed,
                     straggler_frac=frac,
                     churn_leave_rate=0.8 if churn else 0.0,
                     churn_join_rate=0.8 if churn else 0.0,
                     barrier=make_barrier(name, staleness=3, sample_size=2))


def _check_equivalence(name: str, frac: float, churn: bool,
                       seed: int) -> None:
    """All three engines agree at the distribution level (3-seed average)."""
    cfgs = [_scenario(name, frac, churn, seed + k) for k in range(3)]
    ev = [run_simulation(c) for c in cfgs]
    tol = _TOL[churn]

    def mean(rs, f):
        return float(np.mean([f(r) for r in rs]))

    e_prog = mean(ev, lambda r: r.mean_progress)
    e_err = mean(ev, lambda r: r.final_error)
    e_upd = mean(ev, lambda r: r.total_updates)
    for backend in ("numpy", "jax"):
        vec = run_sweep(cfgs, backend=backend)
        assert all(len(r.steps) == 24 for r in vec)
        v_prog = mean(vec, lambda r: r.mean_progress)
        v_err = mean(vec, lambda r: r.final_error)
        v_upd = mean(vec, lambda r: r.total_updates)
        assert abs(v_prog - e_prog) <= tol["prog"] * e_prog + tol["slack"], \
            (backend, name, frac, churn, seed, e_prog, v_prog)
        assert abs(v_err - e_err) <= tol["err"], \
            (backend, name, frac, churn, seed, e_err, v_err)
        assert abs(v_upd - e_upd) <= tol["upd"] * e_upd + 16, \
            (backend, name, frac, churn, seed, e_upd, v_upd)


if HAVE_HYPOTHESIS:

    class TestCrossEngineEquivalence:
        @given(name=st.sampled_from(FIVE),
               frac=st.sampled_from((0.0, 0.2)),
               churn=st.booleans(),
               seed=st.integers(0, 997))
        @settings(max_examples=N_EXAMPLES, deadline=None)
        def test_three_engines_agree(self, name, frac, churn, seed):
            _check_equivalence(name, frac, churn, seed)

else:

    def _fallback_matrix():
        """Deterministic stand-in for the hypothesis scenario draw."""
        rng = np.random.default_rng(2024)
        combos = list(itertools.product(FIVE, (0.0, 0.2), (False, True)))
        picks = rng.choice(len(combos), size=N_EXAMPLES, replace=False) \
            if N_EXAMPLES <= len(combos) else range(len(combos))
        return [combos[i] + (int(rng.integers(0, 998)),) for i in picks]

    class TestCrossEngineEquivalence:
        @pytest.mark.parametrize("name,frac,churn,seed", _fallback_matrix())
        def test_three_engines_agree(self, name, frac, churn, seed):
            _check_equivalence(name, frac, churn, seed)


class TestAdaptivePolicies:
    """Adaptive barrier policies (DSSP / Elastic-BSP / β-annealing):
    the three engines agree at the distribution level, and pinning an
    adaptive policy's range reduces it to its static parent bit-for-bit
    on both grid backends and on the event simulator."""

    @pytest.mark.parametrize("name,frac,churn,seed", [
        ("dssp", 0.2, False, 101),
        ("ebsp", 0.0, False, 202),
        ("apssp", 0.2, True, 303),
        ("apbsp", 0.0, True, 404),
    ])
    def test_three_engines_agree(self, name, frac, churn, seed):
        _check_equivalence(name, frac, churn, seed)

    #: (adaptive kwargs, static-parent kwargs): equal-by-construction pairs
    REDUCTIONS = [
        (dict(staleness=3, staleness_lo=3), dict(staleness=3)),
        (dict(max_advance=0), dict()),
        (dict(staleness=3, sample_size=2, sample_size_lo=2),
         dict(staleness=3, sample_size=2)),
    ]
    NAMES = [("dssp", "ssp"), ("ebsp", "bsp"), ("apssp", "pssp")]

    @staticmethod
    def _pair(i, frac, churn, seed):
        (akw, skw) = TestAdaptivePolicies.REDUCTIONS[i]
        an, sn = TestAdaptivePolicies.NAMES[i]
        base = dict(n_nodes=12, duration=4.0, dim=8, batch=4, seed=seed,
                    straggler_frac=frac,
                    churn_leave_rate=0.8 if churn else 0.0,
                    churn_join_rate=0.8 if churn else 0.0)
        return (SimConfig(barrier=make_barrier(an, **akw), **base),
                SimConfig(barrier=make_barrier(sn, **skw), **base))

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    @pytest.mark.parametrize("i", range(3))
    @pytest.mark.parametrize("frac,churn", [(0.2, False), (0.2, True)])
    def test_pinned_range_reduces_to_static_parent(self, i, frac, churn,
                                                   backend):
        """DSSP r==s ≡ SSP, Elastic-BSP R=0 ≡ BSP, β_min==β_max ≡ parent
        — bit-for-bit: the adaptive carry rides along but every decision
        (and every RNG draw) is the static row's."""
        a_cfg, s_cfg = self._pair(i, frac, churn, seed=7 * i + churn)
        a = run_sweep([a_cfg], backend=backend)[0]
        s = run_sweep([s_cfg], backend=backend)[0]
        np.testing.assert_array_equal(a.steps, s.steps)
        np.testing.assert_array_equal(a.errors, s.errors)
        np.testing.assert_array_equal(a.server_updates, s.server_updates)
        assert a.total_updates == s.total_updates
        assert a.control_messages == s.control_messages

    @pytest.mark.parametrize("i", range(3))
    def test_event_sim_reduction(self, i):
        a_cfg, s_cfg = self._pair(i, 0.2, False, seed=11 + i)
        a, s = run_simulation(a_cfg), run_simulation(s_cfg)
        np.testing.assert_array_equal(a.steps, s.steps)
        np.testing.assert_array_equal(a.errors, s.errors)
        assert a.total_updates == s.total_updates

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    def test_adaptive_carry_leaves_static_rows_untouched(self, backend):
        """Mixing an adaptive row into a batch flips the whole batch onto
        the policy-carry code path — the static rows must not notice: a
        [dssp(r==s), ssp] batch equals an [ssp, ssp] batch row-for-row,
        bit-for-bit (the carry adds no RNG draws and no decisions)."""
        base = dict(n_nodes=12, duration=4.0, dim=8, batch=4,
                    straggler_frac=0.2)
        mixed = run_sweep(
            [SimConfig(barrier=make_barrier("dssp", staleness=3,
                                            staleness_lo=3),
                       seed=21, **base),
             SimConfig(barrier=make_barrier("ssp", staleness=3), seed=22,
                       **base)], backend=backend)
        pure = run_sweep(
            [SimConfig(barrier=make_barrier("ssp", staleness=3), seed=21,
                       **base),
             SimConfig(barrier=make_barrier("ssp", staleness=3), seed=22,
                       **base)], backend=backend)
        for a, b in zip(mixed, pure):
            np.testing.assert_array_equal(a.steps, b.steps)
            np.testing.assert_array_equal(a.errors, b.errors)
            assert a.total_updates == b.total_updates


class TestSweepInvariance:
    """run_sweep output order/shape is invariant to backend and grouping."""

    CFGS = [  # interleaved structural groups + churn group
        _scenario("pbsp", 0.0, False, 0),
        SimConfig(n_nodes=16, duration=4.0, dim=8,
                  barrier=make_barrier("bsp"), seed=1),
        _scenario("ssp", 0.2, True, 2),
        SimConfig(n_nodes=16, duration=4.0, dim=8,
                  barrier=make_barrier("asp"), seed=3),
        _scenario("pssp", 0.2, False, 4),
    ]

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    def test_order_and_shapes(self, backend):
        res = run_sweep(self.CFGS, backend=backend)
        assert [len(r.steps) for r in res] == [24, 16, 24, 16, 24]
        assert all(r.mean_progress > 0 for r in res)
        for cfg, r in zip(self.CFGS, res):
            m = int(cfg.duration / cfg.measure_interval) + 1
            assert r.times.shape == r.errors.shape == (m,)
            assert r.server_updates[-1] == r.total_updates

    def test_grouping_invariance_jax(self):
        # results must not depend on which rows share a batch
        solo = [run_sweep([c], backend="jax")[0] for c in self.CFGS]
        grouped = run_sweep(self.CFGS, backend="jax")
        for a, b in zip(solo, grouped):
            # same engine, same per-row marginals; identical only when the
            # row is alone in its structural group both times — so compare
            # at the distribution level
            assert abs(a.mean_progress - b.mean_progress) \
                <= 0.25 * a.mean_progress + 1.5

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    def test_determinism(self, backend):
        r1 = run_sweep(self.CFGS, backend=backend)
        r2 = run_sweep(self.CFGS, backend=backend)
        for a, b in zip(r1, r2):
            assert np.array_equal(a.steps, b.steps)
            assert np.array_equal(a.errors, b.errors)
            assert a.total_updates == b.total_updates
            assert a.control_messages == b.control_messages


class TestChurnNative:
    """Churn rows run on the vector engine itself — no event-sim fallback."""

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    def test_vector_simulator_accepts_churn(self, backend):
        cfg = _scenario("pssp", 0.0, True, 5)
        res = VectorSimulator([cfg], backend=backend).run()[0]
        assert res.mean_progress > 0
        assert np.isfinite(res.final_error)

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    def test_full_view_departed_min_unblocks(self, backend):
        """A departed global-min straggler must not gate BSP/SSP waiters:
        with heavy leave churn the masked-min wakeup keeps rows live (a
        stalled engine would show near-zero progress)."""
        cfgs = [_scenario("ssp", 0.2, False, s) for s in range(2)]
        churned = [dataclasses.replace(c, churn_leave_rate=2.0)
                   for c in cfgs]
        base = run_sweep(cfgs, backend=backend)
        churn = run_sweep(churned, backend=backend)
        for b, c in zip(base, churn):
            assert c.mean_progress > 0.4 * b.mean_progress

    def test_distributed_churn_charges_control_plane(self):
        cfg = dataclasses.replace(_scenario("pssp", 0.0, True, 6),
                                  distributed_sampling=True)
        for backend in ("numpy", "jax"):
            res = run_sweep([cfg], backend=backend)[0]
            assert res.control_messages > 0


class TestGoldenTrace:
    """Fixed-seed 3-node pBSP: per-backend step/error traces pinned against
    committed goldens — any silent drift in the tick ordering (or in the
    backends' RNG consumption) flips the integer traces.  Regenerate by
    running this file with ``PSP_REGEN_GOLDEN=1`` after an *intentional*
    RNG-layout change."""

    @staticmethod
    def _run(backend):
        cfg = SimConfig(n_nodes=3, duration=4.0, dim=4, batch=4, seed=11,
                        barrier=make_barrier("pbsp", staleness=2,
                                             sample_size=1))
        return run_sweep([cfg], backend=backend)[0]

    @pytest.fixture(scope="class")
    def golden(self):
        with open(GOLDEN_PATH) as f:
            return json.load(f)

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    def test_trace_matches_golden(self, golden, backend):
        r = self._run(backend)
        if env.flag("PSP_REGEN_GOLDEN"):
            golden[backend] = {
                "steps": r.steps.tolist(),
                "total_updates": int(r.total_updates),
                "server_updates": r.server_updates.tolist(),
                "errors": [float(e) for e in r.errors],
            }
            with open(GOLDEN_PATH, "w") as f:
                json.dump(golden, f, indent=1)
            pytest.skip("golden trace regenerated")
        g = golden[backend]
        assert r.steps.tolist() == g["steps"]
        assert r.total_updates == g["total_updates"]
        assert r.server_updates.tolist() == g["server_updates"]
        assert np.allclose(r.errors, g["errors"], rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    def test_trace_byte_stable(self, backend):
        a, b = self._run(backend), self._run(backend)
        assert a.errors.tobytes() == b.errors.tobytes()
        assert a.steps.tobytes() == b.steps.tobytes()
        assert a.server_updates.tolist() == b.server_updates.tolist()

    def test_backends_agree_on_golden_scenario(self):
        a, b = self._run("numpy"), self._run("jax")
        assert abs(a.mean_progress - b.mean_progress) \
            <= 0.2 * a.mean_progress + 1.0

    def test_node_sharded_mesh_reproduces_golden(self, golden):
        """The 2-D engine on a node-sharded mesh (P = 3 nodes across the
        nodes axis) reproduces the committed 1-D jax golden exactly — no
        regeneration allowed: node sharding must not perturb the RNG
        layout."""
        import jax
        if len(jax.devices()) < 3:
            pytest.skip("needs >=3 devices")
        from repro.core import vector_sim_jax
        ambient = os.environ.get("PSP_SWEEP_MESH")
        os.environ["PSP_SWEEP_MESH"] = "1x3"
        vector_sim_jax._compiled_chunk.cache_clear()
        try:
            r = self._run("jax")
        finally:
            if ambient is None:
                os.environ.pop("PSP_SWEEP_MESH", None)
            else:
                os.environ["PSP_SWEEP_MESH"] = ambient
            vector_sim_jax._compiled_chunk.cache_clear()
        g = golden["jax"]
        assert r.steps.tolist() == g["steps"]
        assert r.total_updates == g["total_updates"]
        assert r.server_updates.tolist() == g["server_updates"]
        assert np.allclose(r.errors, g["errors"], rtol=1e-4, atol=1e-5)

    def test_mesh_trace_matches_golden(self, golden):
        """Dedicated 2-D golden: a churned 24-node pBSP row on a 2×4
        mesh, pinned like the 1-D entries (regen via PSP_REGEN_GOLDEN=1
        only after an intentional RNG-layout change)."""
        import jax
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 devices")
        from repro.core import vector_sim_jax
        cfg = _scenario("pbsp", 0.2, True, 11)
        ambient = os.environ.get("PSP_SWEEP_MESH")
        os.environ["PSP_SWEEP_MESH"] = "2x4"
        vector_sim_jax._compiled_chunk.cache_clear()
        try:
            r = run_sweep([cfg], backend="jax")[0]
        finally:
            if ambient is None:
                os.environ.pop("PSP_SWEEP_MESH", None)
            else:
                os.environ["PSP_SWEEP_MESH"] = ambient
            vector_sim_jax._compiled_chunk.cache_clear()
        if env.flag("PSP_REGEN_GOLDEN"):
            golden["jax_mesh2x4"] = {
                "steps": r.steps.tolist(),
                "total_updates": int(r.total_updates),
                "server_updates": r.server_updates.tolist(),
                "errors": [float(e) for e in r.errors],
            }
            with open(GOLDEN_PATH, "w") as f:
                json.dump(golden, f, indent=1)
            pytest.skip("2-D mesh golden trace regenerated")
        g = golden["jax_mesh2x4"]
        assert r.steps.tolist() == g["steps"]
        assert r.total_updates == g["total_updates"]
        assert r.server_updates.tolist() == g["server_updates"]
        assert np.allclose(r.errors, g["errors"], rtol=1e-4, atol=1e-5)


class TestVarianceBands:
    def test_band_shapes_and_enclosure(self):
        from benchmarks.figures import fig1_error_bands
        out = fig1_error_bands(seeds=(0, 1))
        for name in FIVE:
            band = out[name]
            t = np.asarray(band["times"])
            mean = np.asarray(band["mean"])
            lo, hi = np.asarray(band["lo"]), np.asarray(band["hi"])
            assert t.shape == mean.shape == lo.shape == hi.shape
            assert np.all(lo <= mean + 1e-12)
            assert np.all(mean <= hi + 1e-12)
            assert np.all(lo >= 0.0)
            assert band["final_mean"] == pytest.approx(mean[-1])


class TestDeviceResidency:
    """The jax backend is device-resident: the chunked scans carry the
    FULL state pytree, the chunk loop performs zero host transfers — one
    staged upload before, one ``device_get`` after — and each chunk
    *donates* its carry, so XLA reuses the state buffers instead of
    double-buffering the pytree (acceptance criteria)."""

    #: every array the tick reads or writes must live in the scan carry —
    #: anything missing would force a host round-trip per tick
    FULL_STATE = {"w", "pulled", "steps", "alive", "computing",
                  "event_time", "ready", "blocked", "total_updates",
                  "control", "pend_leave", "pend_join"}
    #: adaptive batches additionally carry the policy state on device
    POLICY_STATE = {"pol_thr", "pol_ema", "pol_beta"}

    @pytest.mark.parametrize("churn", (False, True))
    @pytest.mark.parametrize("name", ("pssp", "ebsp"))
    def test_chunked_scans_carry_full_state_and_no_transfers(self, name,
                                                             churn):
        import jax
        from repro.core import vector_sim_jax

        cfg = _scenario(name, 0.2, churn, 7)
        sim = VectorSimulator([cfg], backend="jax")
        chunk_fn, plan, params, carry, xs_chunks = \
            vector_sim_jax._prepare(sim)
        want = self.FULL_STATE | (self.POLICY_STATE if name == "ebsp"
                                  else set())
        assert set(carry) == want
        warm = {k: v.copy() for k, v in carry.items()}
        for xs in xs_chunks:             # compile every shape off-guard
            warm, _ = chunk_fn(params, warm, xs)
        with jax.transfer_guard("disallow"):
            c, recs = carry, 0
            for xs in xs_chunks:
                c, (err_r, upd_r) = chunk_fn(params, c, xs)
                recs += err_r.shape[0]
            jax.block_until_ready(c)
        assert set(c) == want
        assert recs == plan.n_rec
        assert plan.n_rec * plan.stride >= sim.ticks.size

    def test_chunk_carry_is_donated_not_rematerialized(self):
        """The donated carry's input buffers must actually be consumed —
        a dropped donation would silently double-buffer the (B, P)
        state pytree every chunk."""
        import jax
        from repro.core import vector_sim_jax

        cfg = _scenario("pssp", 0.2, False, 7)
        sim = VectorSimulator([cfg], backend="jax")
        chunk_fn, plan, params, carry, xs_chunks = \
            vector_sim_jax._prepare(sim)
        warm = {k: v.copy() for k, v in carry.items()}
        warm, _ = chunk_fn(params, warm, xs_chunks[0])
        new_carry, _ = chunk_fn(params, carry, xs_chunks[0])
        assert all(v.is_deleted() for v in carry.values())
        assert not any(v.is_deleted() for v in new_carry.values())

    def test_run_batch_matches_staged_chunks(self):
        """run_batch's production output equals what the staged
        _prepare + chunk-loop path computes (same scans, same trace
        selection) — one device_get moves everything at the end."""
        import jax
        from repro.core import vector_sim_jax

        cfg = _scenario("pbsp", 0.0, False, 8)
        res = run_sweep([cfg], backend="jax")[0]
        sim = VectorSimulator([cfg], backend="jax")
        chunk_fn, plan, params, carry, xs_chunks = \
            vector_sim_jax._prepare(sim)
        errs_r = []
        for xs in xs_chunks:
            carry, (err_r, _) = chunk_fn(params, carry, xs)
            errs_r.append(err_r)
        final, errs_r = jax.device_get((carry, errs_r))
        err_t = np.concatenate(errs_r)[:plan.n_rec_live]
        m_idx = np.searchsorted(sim.ticks, sim.m_times[1:] - 1e-9)
        r_idx = (m_idx + 1) // plan.stride - 1
        errs = np.concatenate(
            [[1.0], np.asarray(err_t, np.float64).T[0, r_idx]])
        np.testing.assert_allclose(res.errors, errs, rtol=0, atol=0)
        assert np.array_equal(res.steps, np.asarray(final["steps"])[0])
        assert res.total_updates == int(final["total_updates"][0])


class TestShardedSweeps:
    """The B dimension shards over a 1-D mesh; per-row/per-node keyed
    noise makes every mesh size consume identical draws, so sharded
    sweeps are bit-identical to the single-device engine.  The CI
    multi-device lane runs this with 8 forced host devices."""

    CFGS = [_scenario("pssp", 0.2, False, s) for s in range(4)] + \
        [_scenario("bsp", 0.1, True, 9)]

    @staticmethod
    def _run(monkeypatch, ndev):
        from repro.core import vector_sim_jax
        # an ambient PSP_SWEEP_MESH (the CI factorization matrix) would
        # override PSP_SWEEP_DEVICES and make these 1-D tests vacuous
        monkeypatch.delenv("PSP_SWEEP_MESH", raising=False)
        monkeypatch.setenv("PSP_SWEEP_DEVICES", str(ndev))
        vector_sim_jax._compiled_chunk.cache_clear()
        try:
            return run_sweep(TestShardedSweeps.CFGS, backend="jax")
        finally:
            vector_sim_jax._compiled_chunk.cache_clear()

    def test_mesh_size_bit_identity(self, monkeypatch):
        import jax
        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device "
                        "(XLA_FLAGS=--xla_force_host_platform_device_count)")
        single = self._run(monkeypatch, 1)
        for ndev in (2, len(jax.devices())):
            sharded = self._run(monkeypatch, ndev)
            for a, b in zip(single, sharded):
                np.testing.assert_array_equal(a.steps, b.steps)
                np.testing.assert_array_equal(a.errors, b.errors)
                np.testing.assert_array_equal(a.server_updates,
                                              b.server_updates)
                assert a.total_updates == b.total_updates
                assert a.control_messages == b.control_messages

    def test_odd_row_count_pads_evenly(self, monkeypatch):
        """B not divisible by the mesh pads with inert rows — results
        for the real rows must be unaffected (bit-identical)."""
        import jax
        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device")
        from repro.core import vector_sim_jax
        cfgs = self.CFGS[:3]             # 3 rows on a 2-device mesh
        monkeypatch.delenv("PSP_SWEEP_MESH", raising=False)
        monkeypatch.setenv("PSP_SWEEP_DEVICES", "1")
        vector_sim_jax._compiled_chunk.cache_clear()
        single = run_sweep(cfgs, backend="jax")
        monkeypatch.setenv("PSP_SWEEP_DEVICES", "2")
        vector_sim_jax._compiled_chunk.cache_clear()
        padded = run_sweep(cfgs, backend="jax")
        vector_sim_jax._compiled_chunk.cache_clear()
        for a, b in zip(single, padded):
            np.testing.assert_array_equal(a.steps, b.steps)
            np.testing.assert_array_equal(a.errors, b.errors)


class TestNodeShardedSweeps:
    """2-D ``(rows × nodes)`` mesh: the P node dimension shards too.

    Node-sliced state, collective reductions and node-keyed draws must be
    bit-for-bit identical to the single-device engine across EVERY
    factorization of the same device count — including churn (masked
    sampling), ragged merged batches, adaptive policies and the
    gather-run-slice kernel path.  The CI sharded lane runs this with 8
    forced host devices, once per mesh in its factorization matrix."""

    MESHES = ("8x1", "4x2", "2x4", "1x8")

    @staticmethod
    def _need(n):
        import jax
        if len(jax.devices()) < n:
            pytest.skip(f"needs {n} devices "
                        "(XLA_FLAGS=--xla_force_host_platform_device_count)")

    @staticmethod
    def _sweep(cfgs, mesh, impl=None):
        """run_sweep under a pinned mesh, snapshotting every result field
        that the equivalence contract covers."""
        from repro.core import vector_sim_jax
        saved = {k: os.environ.get(k)
                 for k in ("PSP_SWEEP_MESH", "PSP_TICK_IMPL")}
        os.environ["PSP_SWEEP_MESH"] = mesh
        if impl is not None:
            os.environ["PSP_TICK_IMPL"] = impl
        vector_sim_jax._compiled_chunk.cache_clear()
        try:
            return [(r.steps.copy(), r.errors.copy(),
                     r.server_updates.copy(), int(r.total_updates),
                     int(r.control_messages))
                    for r in run_sweep(cfgs, backend="jax")]
        finally:
            for k, v in saved.items():
                if v is None:
                    os.environ.pop(k, None)
                else:
                    os.environ[k] = v
            vector_sim_jax._compiled_chunk.cache_clear()

    @classmethod
    def _assert_factorizations_identical(cls, cfgs, meshes, impl=None):
        base = cls._sweep(cfgs, "1x1")
        for mesh in meshes:
            other = cls._sweep(cfgs, mesh, impl=impl)
            for b, o in zip(base, other):
                for x, y in zip(b, o):
                    assert np.array_equal(x, y), (mesh, impl, x, y)

    def test_factorization_bit_identity(self):
        """Static barriers (incl. a churn row → masked sampling and a
        k=1 row → the draw fast path) across every 8-device
        factorization."""
        self._need(8)
        cfgs = [_scenario("pssp", 0.2, False, 7),
                _scenario("ssp", 0.0, False, 8),
                _scenario("pbsp", 0.2, True, 9),
                _scenario("asp", 0.1, False, 3)]
        self._assert_factorizations_identical(cfgs, self.MESHES)

    def test_ragged_merge_bit_identity(self):
        """Ragged merged batches (different n_nodes in one compiled scan,
        with churn): padded dead slots shard like live ones."""
        self._need(8)
        cfgs = [SimConfig(n_nodes=n, duration=3.0, dim=6, batch=4, seed=i,
                          straggler_frac=0.2,
                          churn_leave_rate=0.5 if i % 2 else 0.0,
                          churn_join_rate=0.5 if i % 2 else 0.0,
                          barrier=make_barrier("pssp", staleness=3,
                                               sample_size=2))
                for i, n in enumerate((9, 12, 16, 12))]
        self._assert_factorizations_identical(cfgs, ("4x2", "1x8"))

    def test_adaptive_policies_bit_identity(self):
        """Stateful barrier policies carry per-row/per-node policy state
        through the sharded scan."""
        self._need(8)
        cfgs = [_scenario("dssp", 0.2, False, 11),
                _scenario("ebsp", 0.0, False, 12),
                _scenario("apssp", 0.2, True, 13),
                _scenario("apbsp", 0.0, False, 14)]
        self._assert_factorizations_identical(cfgs, ("4x2", "1x8"))

    def test_interpret_kernel_bit_identity(self):
        """The Pallas-kernel path under a 2-D mesh (gather → full-width
        tick → slice) against the unsharded reference."""
        self._need(8)
        cfgs = [_scenario("pssp", 0.2, False, 7),
                _scenario("pbsp", 0.2, True, 9)]
        self._assert_factorizations_identical(cfgs, ("2x4",),
                                              impl="interpret")

    def test_merged_horizons_bit_identity(self):
        """Rows with different durations freeze independently per shard;
        the early-exit boundary must not depend on the factorization."""
        self._need(8)
        cfgs = [dataclasses.replace(_scenario("pssp", 0.2, False, s),
                                    duration=dur)
                for s, dur in enumerate((5.0, 2.5, 5.0, 1.5))]
        self._assert_factorizations_identical(cfgs, ("4x2", "1x8"))


if HAVE_HYPOTHESIS:

    class TestNodeShardedScenarioMatrix:
        """Hypothesis-driven barrier × straggler × churn × seed × mesh
        matrix: every drawn scenario must be bit-identical between the
        single-device engine and a drawn 2-D factorization."""

        @given(name=st.sampled_from(FIVE + ("dssp", "apssp")),
               frac=st.sampled_from((0.0, 0.2)),
               churn=st.booleans(),
               seed=st.integers(0, 997),
               mesh=st.sampled_from(TestNodeShardedSweeps.MESHES))
        @settings(max_examples=max(2, N_EXAMPLES // 2), deadline=None)
        def test_scenario_bit_identity(self, name, frac, churn, seed, mesh):
            TestNodeShardedSweeps._need(8)
            cfgs = [_scenario(name, frac, churn, seed)]
            TestNodeShardedSweeps._assert_factorizations_identical(
                cfgs, (mesh,))

else:

    class TestNodeShardedScenarioMatrix:
        @pytest.mark.parametrize("name,frac,churn,seed,mesh", [
            (n, f, c, s, m) for (n, f, c, s), m in zip(
                _fallback_matrix(),
                itertools.cycle(TestNodeShardedSweeps.MESHES))
        ][:max(2, N_EXAMPLES // 2)])
        def test_scenario_bit_identity(self, name, frac, churn, seed, mesh):
            TestNodeShardedSweeps._need(8)
            cfgs = [_scenario(name, frac, churn, seed)]
            TestNodeShardedSweeps._assert_factorizations_identical(
                cfgs, (mesh,))


class TestMergedHorizons:
    """Durations merge on the jax backend: the grid runs to the group
    max, shorter rows freeze at their own horizon, and the chunk loop's
    early exit skips scheduled blocks once every row is done."""

    @staticmethod
    def _cfgs():
        return [dataclasses.replace(_scenario("pssp", 0.2, False, s),
                                    duration=dur)
                for s, dur in enumerate((5.0, 2.5, 5.0, 1.5))]

    def test_one_compile_and_per_row_trace_lengths(self):
        from repro.core import vector_sim_jax
        from repro.core.vector_sim import _merge_key

        cfgs = self._cfgs()
        assert len({_merge_key(c) for c in cfgs}) == 1
        vector_sim_jax._compiled_chunk.cache_clear()
        res = run_sweep(cfgs, backend="jax")
        assert vector_sim_jax._compiled_chunk.cache_info().misses == 1
        for cfg, r in zip(cfgs, res):
            m = int(cfg.duration / cfg.measure_interval) + 1
            assert r.times.shape == r.errors.shape == (m,)
            assert r.times[-1] == pytest.approx(cfg.duration)
            assert r.server_updates[-1] == r.total_updates
            assert r.mean_progress > 0

    def test_merged_rows_match_solo_distributionally(self):
        cfgs = self._cfgs()
        merged = run_sweep(cfgs, backend="jax")
        solo = [run_sweep([c], backend="jax")[0] for c in cfgs]
        for a, b in zip(solo, merged):
            assert abs(a.mean_progress - b.mean_progress) \
                <= 0.3 * a.mean_progress + 2.0

    def test_early_exit_skips_dead_chunks(self, monkeypatch):
        """A plan over-scheduled past every row's horizon must stop at
        the all-rows-done boundary — dead chunks are never executed —
        without changing any result."""
        from repro.core import sweep_plan, vector_sim_jax

        cfg = _scenario("pssp", 0.2, False, 3)
        base = run_sweep([cfg], backend="jax")[0]
        real_plan = sweep_plan.plan_sweep
        calls = {"n": 0}

        def over_scheduled(*a, **kw):
            plan = real_plan(*a, **kw)
            return dataclasses.replace(
                plan, chunks=plan.chunks + (plan.chunks[-1],) * 2,
                n_rec=plan.n_rec + 2 * plan.chunks[-1])

        monkeypatch.setattr(vector_sim_jax, "plan_sweep", over_scheduled)
        orig_fn = vector_sim_jax._compiled_chunk

        def counting(*a, **kw):
            fn, mesh = orig_fn(*a, **kw)

            def wrapped(*fa):
                calls["n"] += 1
                return fn(*fa)
            return wrapped, mesh

        monkeypatch.setattr(vector_sim_jax, "_compiled_chunk", counting)
        res = run_sweep([cfg], backend="jax")[0]
        plan = real_plan(250, np.arange(24, 250, 25), 1, 24, batch=4,
                         d=8, k_max=2, masked=False, has_churn=False)
        assert calls["n"] == len(plan.chunks)   # dead tail chunks skipped
        np.testing.assert_array_equal(res.steps, base.steps)
        np.testing.assert_array_equal(res.errors, base.errors)


class TestRaggedMerge:
    """Groups differing only in n_nodes (and churn-ness) merge into ONE
    compiled scan on the jax backend — padded slots are dead alive-mask
    entries the barrier, sampler and join pool all ignore."""

    @staticmethod
    def _cfgs():
        return [SimConfig(n_nodes=n, duration=3.0, dim=6, batch=4, seed=i,
                          barrier=make_barrier("pssp", staleness=3,
                                               sample_size=2))
                for i, n in enumerate((9, 12, 16, 12))]

    def test_single_compile_and_correct_shapes(self):
        from repro.core import vector_sim_jax
        from repro.core.vector_sim import _merge_key

        cfgs = self._cfgs()
        assert len({_merge_key(c) for c in cfgs}) == 1
        vector_sim_jax._compiled_chunk.cache_clear()
        res = run_sweep(cfgs, backend="jax")
        assert vector_sim_jax._compiled_chunk.cache_info().misses == 1
        assert [len(r.steps) for r in res] == [9, 12, 16, 12]
        for r in res:
            assert r.mean_progress > 0
            assert np.isfinite(r.final_error)

    def test_ragged_rows_match_solo_distributionally(self):
        cfgs = self._cfgs()
        merged = run_sweep(cfgs, backend="jax")
        solo = [run_sweep([c], backend="jax")[0] for c in cfgs]
        for a, b in zip(solo, merged):
            assert abs(a.mean_progress - b.mean_progress) \
                <= 0.3 * a.mean_progress + 2.0

    def test_ragged_with_churn_keeps_population_bounds(self):
        # joins must never resurrect a padded slot beyond the row's true P
        cfgs = [dataclasses.replace(c, churn_join_rate=2.0,
                                    churn_leave_rate=0.5)
                for c in self._cfgs()[:2]]
        res = run_sweep(cfgs, backend="jax")
        for cfg, r in zip(cfgs, res):
            assert len(r.steps) == cfg.n_nodes
            assert r.mean_progress > 0

    def test_numpy_backend_rejects_ragged(self):
        with pytest.raises(ValueError, match="heterogeneous"):
            VectorSimulator(self._cfgs()[:2], backend="numpy")
