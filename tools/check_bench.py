#!/usr/bin/env python
"""Benchmark-regression gate for the sweep engines.

Compares a *fresh* ``benchmarks.sweep_bench`` smoke run against the
committed baseline ``BENCH_sweep.json`` and fails when a grid engine's
throughput regressed by more than its tolerance.

The compared metric is ``speedup_vs_event`` — each engine's throughput
normalized by the event-driven reference timed *in the same run on the
same machine* — so the committed baseline transfers across hosts: a slow
CI runner slows the event loop and the grid engines alike, while a real
regression (extra compiles, host transfers, a de-vectorized tick) drops
only the grid engine's ratio.  Gated engines default to ``numpy`` and
``jax`` at 25% tolerance plus ``pallas`` at a looser 45% — the
Pallas-interpret row is noisier on CPU (the interpreter lowers the
kernel through extra masking), but a kernel-path collapse (e.g. a
change that silently de-fuses the tick) still has to fail CI.

Usage (the CI fast lane runs exactly this)::

    python -m benchmarks.sweep_bench --out bench_fresh.json
    python tools/check_bench.py --fresh bench_fresh.json

Engine selection accepts optional per-engine tolerances:
``--engines numpy,jax,pallas:0.45`` gates the first two at
``--tolerance`` and pallas at 45%.  Without ``--fresh`` the gate runs
the smoke benchmark itself (pallas row included) and writes the fresh
JSON next to the baseline as ``BENCH_fresh.json``.  Exit status 0 when
every gated engine is within tolerance, 1 otherwise (one ``FAIL`` line
per regressed engine), mirroring the doc-coverage gate's contract.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_PATH = os.path.join(REPO_ROOT, "BENCH_sweep.json")
DEFAULT_TOLERANCE = 0.25
#: per-engine default tolerance overrides (looser for the noisy
#: interpret-mode kernel row)
ENGINE_TOLERANCE = {"pallas": 0.45}
DEFAULT_ENGINES = ("numpy", "jax", "pallas")
METRIC = "speedup_vs_event"


def load_engines(path: str) -> Dict[str, Dict]:
    """Read a ``BENCH_sweep.json``-schema file and return its engine map."""
    with open(path) as f:
        data = json.load(f)
    engines = data.get("engines")
    if not isinstance(engines, dict):
        raise ValueError(f"{path}: no 'engines' table "
                         "(not a sweep_bench JSON?)")
    return engines


def parse_engines(spec: str, tolerance: float) -> List[Tuple[str, float]]:
    """``name[:tol],...`` → [(engine, tolerance)].

    A bare name takes its :data:`ENGINE_TOLERANCE` default (falling back
    to the global ``tolerance``); an explicit ``:tol`` suffix wins.
    """
    out = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if ":" in item:
            name, tol = item.split(":", 1)
            out.append((name, float(tol)))
        else:
            out.append((item, ENGINE_TOLERANCE.get(item, tolerance)))
    return out


def check(baseline: Dict[str, Dict], fresh: Dict[str, Dict],
          engines: List[Tuple[str, float]]) -> List[str]:
    """Return one failure line per engine regressed beyond its tolerance.

    An engine missing from the *fresh* run is a failure — a silently
    dropped benchmark row must not read as a pass.  An engine missing
    from the *baseline* only is skipped with a note: that's a row a
    newer PR added which the committed baseline predates; it starts
    being gated once the baseline is regenerated, and failing on it
    would force every row addition into a lock-step baseline bump.
    """
    jb, jf = baseline.get("jax", {}), fresh.get("jax", {})
    if jb.get("n_devices") != jf.get("n_devices"):
        # the event-loop normalization cancels host *speed* but not mesh
        # size: more devices only loosen this one-sided gate, fewer can
        # trip it without a real regression — surface it either way
        print(f"WARN jax: mesh size differs (baseline "
              f"n_devices={jb.get('n_devices')}, fresh "
              f"{jf.get('n_devices')}); speedups are not directly "
              "comparable — recalibrate the baseline on this runner "
              "class (docs/BENCHMARKS.md)")
    failures = []
    for name, tolerance in engines:
        base_row, fresh_row = baseline.get(name), fresh.get(name)
        if fresh_row is None:
            line = f"FAIL {name}: engine row missing from fresh run"
            print(line)
            failures.append(line)
            continue
        if base_row is None:
            print(f"skip {name}: not in baseline (row newer than the "
                  "committed BENCH_sweep.json; regenerate the baseline "
                  "to gate it)")
            continue
        base, got = base_row.get(METRIC), fresh_row.get(METRIC)
        if base is None or got is None:
            line = f"FAIL {name}: no {METRIC} in row"
            print(line)
            failures.append(line)
            continue
        floor = base * (1.0 - tolerance)
        status = "ok" if got >= floor else "FAIL"
        line = (f"{status} {name}: {METRIC} {got:.2f}x vs baseline "
                f"{base:.2f}x (floor {floor:.2f}x at "
                f"{tolerance:.0%} tolerance)")
        print(line)
        if status == "FAIL":
            failures.append(line)
    return failures


def main(argv=None) -> int:
    """CLI entry: compare fresh vs committed sweep-bench throughput."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=BASELINE_PATH,
                    help="committed baseline JSON (default: repo root)")
    ap.add_argument("--fresh", default=None,
                    help="fresh sweep_bench JSON; omitted = run the smoke "
                         "benchmark now (pallas row included)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional throughput drop for engines "
                         "without a per-engine override (default 0.25; "
                         "pallas defaults to 0.45)")
    ap.add_argument("--engines", default=",".join(DEFAULT_ENGINES),
                    help="comma-separated engine rows to gate, each "
                         "optionally suffixed :tolerance "
                         "(e.g. numpy,jax,pallas:0.5)")
    a = ap.parse_args(argv)

    baseline = load_engines(a.baseline)
    if a.fresh is None:
        # self-run mode: make both the benchmarks package and the
        # src-layout repro package importable from a bare checkout
        sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
        sys.path.insert(0, REPO_ROOT)
        from benchmarks.sweep_bench import sweep_speedup
        fresh_path = os.path.join(REPO_ROOT, "BENCH_fresh.json")
        print(f"running smoke sweep_bench -> {fresh_path}", file=sys.stderr)
        fresh = sweep_speedup(pallas=True, out_path=fresh_path)["engines"]
    else:
        fresh = load_engines(a.fresh)

    failures = check(baseline, fresh, parse_engines(a.engines, a.tolerance))
    if failures:
        print(f"bench-regression gate: {len(failures)} engine(s) regressed "
              "beyond tolerance", file=sys.stderr)
        return 1
    print("bench-regression gate: all engines within tolerance",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
