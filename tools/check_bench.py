#!/usr/bin/env python
"""Benchmark-regression gate for the sweep engines.

Compares a *fresh* ``benchmarks.sweep_bench`` smoke run against the
committed baseline ``BENCH_sweep.json`` and fails when a grid engine's
throughput regressed by more than its tolerance.

The compared metric is ``speedup_vs_event`` — each engine's throughput
normalized by the event-driven reference timed *in the same run on the
same machine* — so the committed baseline transfers across hosts: a slow
CI runner slows the event loop and the grid engines alike, while a real
regression (extra compiles, host transfers, a de-vectorized tick) drops
only the grid engine's ratio.  Gated engines default to ``numpy`` and
``jax`` at 25% tolerance plus ``pallas`` at a looser 45% — the
Pallas-interpret row is noisier on CPU (the interpreter lowers the
kernel through extra masking), but a kernel-path collapse (e.g. a
change that silently de-fuses the tick) still has to fail CI.

Jax-family rows additionally carry 2-D mesh metadata (``mesh`` =
``[rows, nodes]`` plus a ``mesh_axes`` table; see
``benchmarks/sweep_bench.py --mesh``): a gated jax row *missing* that
metadata fails — a silently un-meshed benchmark must not read as a
pass — and when baseline and fresh ran different device counts the
gated metric is normalized per device before comparison, so baselines
transfer across mesh factorizations and runner sizes.  The 100k-node
``jax_100k`` smoke row has no event-loop reference (that's its point);
it is gated on ``node_steps_per_device_sec`` — already per-device, with
a bit-identical numerator across factorizations — at a loose 60%
tolerance that still catches a node-sharding collapse.  The CI
factorization matrix runs ``--mesh-only`` instead: its lanes force N
host devices onto one physical CPU, so per-device throughput drops ~Nx
by construction and only row presence + mesh coherence are meaningful
there.

Usage (the CI fast lane runs exactly this)::

    python -m benchmarks.sweep_bench --out bench_fresh.json
    python tools/check_bench.py --fresh bench_fresh.json

Engine selection accepts optional per-engine tolerances:
``--engines numpy,jax,pallas:0.45`` gates the first two at
``--tolerance`` and pallas at 45%.  Without ``--fresh`` the gate runs
the smoke benchmark itself (pallas row included) and writes the fresh
JSON next to the baseline as ``BENCH_fresh.json``.  Exit status 0 when
every gated engine is within tolerance, 1 otherwise (one ``FAIL`` line
per regressed engine), mirroring the doc-coverage gate's contract.

``--serve`` gates the *serving-tier* benchmark instead
(``benchmarks.serve_bench`` vs the committed
``results/benchmarks/serve.json``).  Its run **invariants** are gated
unconditionally — at least two mid-stream snapshot swaps, traffic
spanning at least two model versions, and zero dropped requests — while
the ``tokens_per_s`` floor (loose 60% tolerance: serve has no same-run
event-loop normalizer, so raw throughput varies more across hosts) only
applies when the fresh run matches the baseline's load shape
(requests/rate/batch/max-new); a ``--smoke`` fresh run gates invariants
only::

    python -m benchmarks.serve_bench --smoke --out serve_fresh.json
    python tools/check_bench.py --serve --fresh serve_fresh.json

``--chaos`` gates the chaos benchmark (``benchmarks.chaos_bench`` vs the
committed ``results/benchmarks/chaos.json``).  Its **invariants** gate
unconditionally — both cluster runs completed, the SIGKILLed worker
rejoined *and* contributed a push (a recovery latency exists), live
workers were never restarted, the serving stream finished with zero
drops after at least one hot-swap and one decode-worker restart, and
the torn-snapshot storm actually fired — while the floors (cluster
``goodput_ratio`` and the ``recovery_latency_s`` ceiling) only apply
when the fresh run matches the baseline's shape; a ``--smoke`` fresh
run gates invariants only::

    python -m benchmarks.chaos_bench --smoke --out chaos_fresh.json
    python tools/check_bench.py --chaos --fresh chaos_fresh.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_PATH = os.path.join(REPO_ROOT, "BENCH_sweep.json")
SERVE_BASELINE_PATH = os.path.join(REPO_ROOT, "results", "benchmarks",
                                   "serve.json")
CHAOS_BASELINE_PATH = os.path.join(REPO_ROOT, "results", "benchmarks",
                                   "chaos.json")
DEFAULT_TOLERANCE = 0.25
#: serve throughput floor tolerance — loose: no same-run normalizer
SERVE_TOLERANCE = 0.6
#: a fresh serve run only gates throughput at the baseline's load shape
SERVE_SCALE_KEYS = ("requests", "rate_rps", "batch", "max_new_tokens")
#: chaos goodput-ratio floor tolerance — the ratio IS same-run
#: normalized (faulted vs no-fault on the same host), so it transfers,
#: but respawn wall time (process spawn + jax import) varies with load
CHAOS_TOLERANCE = 0.5
#: recovery latency may grow this factor over baseline before failing
CHAOS_LATENCY_SLACK = 3.0
#: cluster-shape keys that must match for the chaos floors to apply
CHAOS_SCALE_KEYS = ("workers", "ticks", "dim", "batch")
#: per-engine default tolerance overrides (looser for the noisy
#: interpret-mode kernel row; loosest for the raw-throughput 100k row,
#: whose metric has no same-run event normalization)
ENGINE_TOLERANCE = {"pallas": 0.45, "jax_100k": 0.6}
DEFAULT_ENGINES = ("numpy", "jax", "pallas", "jax_100k")
METRIC = "speedup_vs_event"
#: per-engine gated-metric overrides
ENGINE_METRIC = {"jax_100k": "node_steps_per_device_sec"}
#: engines that must carry 2-D mesh metadata (mesh + mesh_axes)
MESH_ENGINES = ("jax", "pallas", "jax_100k")
#: metrics already normalized per device (skip the device renorm)
PER_DEVICE_METRICS = ("node_steps_per_device_sec",)


def load_engines(path: str) -> Dict[str, Dict]:
    """Read a ``BENCH_sweep.json``-schema file and return its engine map."""
    with open(path) as f:
        data = json.load(f)
    engines = data.get("engines")
    if not isinstance(engines, dict):
        raise ValueError(f"{path}: no 'engines' table "
                         "(not a sweep_bench JSON?)")
    return engines


def parse_engines(spec: str, tolerance: float) -> List[Tuple[str, float]]:
    """``name[:tol],...`` → [(engine, tolerance)].

    A bare name takes its :data:`ENGINE_TOLERANCE` default (falling back
    to the global ``tolerance``); an explicit ``:tol`` suffix wins.
    """
    out = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if ":" in item:
            name, tol = item.split(":", 1)
            out.append((name, float(tol)))
        else:
            out.append((item, ENGINE_TOLERANCE.get(item, tolerance)))
    return out


def mesh_errors(name: str, row: Dict) -> List[str]:
    """Validate a jax-family row's 2-D mesh metadata; [] when coherent.

    Requires ``mesh`` (a ``[rows, nodes]`` pair of positive ints),
    ``mesh_axes`` naming the same sizes, and ``n_devices`` equal to
    their product — so a row can't silently claim a placement it did
    not run.
    """
    mesh = row.get("mesh")
    axes = row.get("mesh_axes")
    if (not isinstance(mesh, (list, tuple)) or len(mesh) != 2
            or not all(isinstance(m, int) and m >= 1 for m in mesh)):
        return [f"FAIL {name}: missing/malformed mesh metadata "
                f"(mesh={mesh!r}; expected [rows, nodes])"]
    errs = []
    if (not isinstance(axes, dict)
            or [axes.get("rows"), axes.get("nodes")] != list(mesh)):
        errs.append(f"FAIL {name}: mesh_axes {axes!r} does not name "
                    f"mesh {list(mesh)}")
    if row.get("n_devices") != mesh[0] * mesh[1]:
        errs.append(f"FAIL {name}: n_devices {row.get('n_devices')!r} "
                    f"!= rows*nodes {mesh[0] * mesh[1]}")
    return errs


def check(baseline: Dict[str, Dict], fresh: Dict[str, Dict],
          engines: List[Tuple[str, float]],
          mesh_only: bool = False) -> List[str]:
    """Return one failure line per engine regressed beyond its tolerance.

    An engine missing from the *fresh* run is a failure — a silently
    dropped benchmark row must not read as a pass.  An engine missing
    from the *baseline* only is skipped with a note: that's a row a
    newer PR added which the committed baseline predates; it starts
    being gated once the baseline is regenerated, and failing on it
    would force every row addition into a lock-step baseline bump.

    Gated jax-family rows must carry coherent mesh metadata
    (:func:`mesh_errors`); when baseline and fresh ran different device
    counts, the gated metric is divided by each run's ``n_devices``
    first (unless the metric is already per-device), so the one-sided
    floor compares per-device throughput rather than letting a bigger
    fresh mesh mask a real regression — or a smaller one fake it.

    ``mesh_only=True`` gates row presence and mesh-metadata coherence
    but skips the throughput floor entirely.  That's the mode for the
    CI factorization matrix, which forces N host devices onto one
    physical CPU: per-device throughput there drops ~Nx by
    construction, so a floor comparison against the committed
    single-device baseline would always fail without measuring
    anything.  Throughput stays gated by the fast lane's 1-device run.
    """
    failures = []
    for name, tolerance in engines:
        base_row, fresh_row = baseline.get(name), fresh.get(name)
        if fresh_row is None:
            line = f"FAIL {name}: engine row missing from fresh run"
            print(line)
            failures.append(line)
            continue
        if name in MESH_ENGINES:
            errs = mesh_errors(name, fresh_row)
            for line in errs:
                print(line)
            failures.extend(errs)
            if errs:
                continue
        if mesh_only:
            mesh = fresh_row.get("mesh")
            print(f"ok {name}: mesh metadata coherent"
                  + (f" (mesh {mesh[0]}x{mesh[1]}, "
                     f"{fresh_row.get('n_devices')} device(s))"
                     if mesh else " (non-mesh row present)"))
            continue
        if base_row is None:
            print(f"skip {name}: not in baseline (row newer than the "
                  "committed BENCH_sweep.json; regenerate the baseline "
                  "to gate it)")
            continue
        metric = ENGINE_METRIC.get(name, METRIC)
        base, got = base_row.get(metric), fresh_row.get(metric)
        if base is None or got is None:
            line = f"FAIL {name}: no {metric} in row"
            print(line)
            failures.append(line)
            continue
        note = ""
        bd, fd = base_row.get("n_devices"), fresh_row.get("n_devices")
        if (name in MESH_ENGINES and bd != fd
                and metric not in PER_DEVICE_METRICS):
            if not (isinstance(bd, int) and isinstance(fd, int)
                    and bd >= 1 and fd >= 1):
                line = (f"FAIL {name}: device counts differ (baseline "
                        f"{bd!r}, fresh {fd!r}) and are not normalizable")
                print(line)
                failures.append(line)
                continue
            base, got = base / bd, got / fd
            note = (f" [per-device: baseline ran {bd} device(s), "
                    f"fresh {fd}]")
        floor = base * (1.0 - tolerance)
        status = "ok" if got >= floor else "FAIL"
        line = (f"{status} {name}: {metric} {got:.2f} vs baseline "
                f"{base:.2f} (floor {floor:.2f} at "
                f"{tolerance:.0%} tolerance){note}")
        print(line)
        if status == "FAIL":
            failures.append(line)
    return failures


def check_serve(baseline: Dict, fresh: Dict,
                tolerance: float = SERVE_TOLERANCE) -> List[str]:
    """Gate a fresh serve-bench result; one failure line per violation.

    Invariants gate unconditionally (they define a *valid* hot-swap run:
    two distinct mid-stream swaps, traffic spanning two model versions,
    zero dropped requests); the ``tokens_per_s`` floor only applies when
    the fresh run reproduced the baseline's load shape
    (:data:`SERVE_SCALE_KEYS`) — a ``--smoke`` run's throughput is
    meaningless and must not fail (or vacuously pass) a comparison.
    """
    failures = []

    def fail(line):
        print(line)
        failures.append(line)

    if fresh.get("swaps", 0) < 2:
        fail(f"FAIL serve: {fresh.get('swaps', 0)} swap(s) observed; the "
             "run must hot-swap at least twice mid-stream")
    if len(fresh.get("versions_served", [])) < 2:
        fail(f"FAIL serve: completed traffic spanned versions "
             f"{fresh.get('versions_served')}; need >= 2")
    if fresh.get("dropped") != 0:
        fail(f"FAIL serve: {fresh.get('dropped')!r} dropped request(s); "
             "a swap must never cancel in-flight work")
    if not failures:
        print(f"ok serve: {fresh.get('swaps')} swaps (max stall "
              f"{fresh.get('swap_stall_s', {}).get('max')}s), "
              f"versions {fresh.get('versions_served')}, 0 dropped")
    if all(fresh.get(k) == baseline.get(k) for k in SERVE_SCALE_KEYS):
        base, got = baseline.get("tokens_per_s"), fresh.get("tokens_per_s")
        if base is None or got is None:
            fail("FAIL serve: no tokens_per_s to compare")
        else:
            floor = base * (1.0 - tolerance)
            status = "ok" if got >= floor else "FAIL"
            line = (f"{status} serve: tokens_per_s {got:.2f} vs baseline "
                    f"{base:.2f} (floor {floor:.2f} at "
                    f"{tolerance:.0%} tolerance)")
            print(line)
            if status == "FAIL":
                failures.append(line)
    else:
        print("skip serve throughput floor: fresh run's load shape "
              "differs from the baseline (smoke run?)")
    return failures


def check_chaos(baseline: Dict, fresh: Dict,
                tolerance: float = CHAOS_TOLERANCE) -> List[str]:
    """Gate a fresh chaos-bench result; one failure line per violation.

    Invariants gate unconditionally — they define a run in which the
    chaos machinery actually worked: both cluster runs completed, the
    killed worker rejoined and contributed (``recovery_latency_s``
    present), no live worker was restarted, the serving stream dropped
    nothing while swapping at least once and surviving at least one
    decode-worker death, and the torn-snapshot storm fired.  The
    ``goodput_ratio`` floor and ``recovery_latency_s`` ceiling apply
    only at the baseline's cluster shape (:data:`CHAOS_SCALE_KEYS`) —
    a ``--smoke`` run's timings are noise and gate nothing.
    """
    failures = []

    def fail(line):
        print(line)
        failures.append(line)

    c, s = fresh.get("cluster", {}), fresh.get("serving", {})
    if not c.get("completed"):
        fail("FAIL chaos: cluster segment did not complete both runs")
    if c.get("recovery_latency_s") is None:
        fail("FAIL chaos: killed worker never rejoined and pushed "
             "(no recovery latency recorded)")
    if c.get("live_restarts", 1) != 0:
        fail(f"FAIL chaos: {c.get('live_restarts')!r} live worker "
             "restart(s); only killed workers may be respawned")
    if s.get("dropped") != 0:
        fail(f"FAIL chaos: {s.get('dropped')!r} dropped request(s) under "
             "serving chaos; the stream must finish complete")
    if s.get("swaps", 0) < 1:
        fail("FAIL chaos: no hot-swap landed under publish chaos")
    if s.get("worker_restarts", 0) < 1:
        fail("FAIL chaos: the decode-worker death never fired/recovered")
    if s.get("publish_faults", {}).get("torn", 0) < 1:
        fail("FAIL chaos: the torn-snapshot storm never fired")
    if not failures:
        print(f"ok chaos invariants: recovery {c.get('recovery_latency_s')}"
              f"s, victims {c.get('victims')}, serving "
              f"{s.get('completed')}/{s.get('requests')} with "
              f"{s.get('swaps')} swap(s), "
              f"{s.get('worker_restarts')} restart(s)")
    bc = baseline.get("cluster", {})
    if all(c.get(k) == bc.get(k) for k in CHAOS_SCALE_KEYS):
        base_r, got_r = bc.get("goodput_ratio"), c.get("goodput_ratio")
        if base_r is not None and got_r is not None:
            floor = base_r * (1.0 - tolerance)
            status = "ok" if got_r >= floor else "FAIL"
            line = (f"{status} chaos: goodput_ratio {got_r:.2f} vs "
                    f"baseline {base_r:.2f} (floor {floor:.2f} at "
                    f"{tolerance:.0%} tolerance)")
            print(line)
            if status == "FAIL":
                failures.append(line)
        base_l, got_l = bc.get("recovery_latency_s"), \
            c.get("recovery_latency_s")
        if base_l is not None and got_l is not None:
            ceil = base_l * CHAOS_LATENCY_SLACK
            status = "ok" if got_l <= ceil else "FAIL"
            line = (f"{status} chaos: recovery_latency_s {got_l:.2f} vs "
                    f"baseline {base_l:.2f} (ceiling {ceil:.2f} at "
                    f"{CHAOS_LATENCY_SLACK:.0f}x slack)")
            print(line)
            if status == "FAIL":
                failures.append(line)
    else:
        print("skip chaos floors: fresh cluster shape differs from the "
              "baseline (smoke run?)")
    return failures


def main(argv=None) -> int:
    """CLI entry: compare fresh vs committed sweep-bench throughput."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=BASELINE_PATH,
                    help="committed baseline JSON (default: repo root)")
    ap.add_argument("--fresh", default=None,
                    help="fresh sweep_bench JSON; omitted = run the smoke "
                         "benchmark now (pallas row included)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional throughput drop for engines "
                         "without a per-engine override (default 0.25; "
                         "pallas defaults to 0.45)")
    ap.add_argument("--engines", default=",".join(DEFAULT_ENGINES),
                    help="comma-separated engine rows to gate, each "
                         "optionally suffixed :tolerance "
                         "(e.g. numpy,jax,pallas:0.5)")
    ap.add_argument("--mesh-only", action="store_true",
                    help="gate row presence + mesh-metadata coherence "
                         "only, skipping the throughput floor (for "
                         "forced-host-device CI lanes, where per-device "
                         "throughput drops by construction)")
    ap.add_argument("--serve", action="store_true",
                    help="gate the serving-tier benchmark instead "
                         "(--fresh is a serve_bench JSON; baseline "
                         "defaults to results/benchmarks/serve.json)")
    ap.add_argument("--chaos", action="store_true",
                    help="gate the chaos benchmark instead (--fresh is "
                         "a chaos_bench JSON; baseline defaults to "
                         "results/benchmarks/chaos.json)")
    a = ap.parse_args(argv)

    if a.chaos:
        base_path = (a.baseline if a.baseline != BASELINE_PATH
                     else CHAOS_BASELINE_PATH)
        with open(base_path) as f:
            baseline = json.load(f)
        if a.fresh is None:
            sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
            sys.path.insert(0, REPO_ROOT)
            from benchmarks.chaos_bench import chaos_suite
            print("running smoke chaos_bench...", file=sys.stderr)
            fresh = chaos_suite(smoke=True)
        else:
            with open(a.fresh) as f:
                fresh = json.load(f)
        failures = check_chaos(baseline, fresh)
        if failures:
            print(f"chaos gate: {len(failures)} check(s) failed",
                  file=sys.stderr)
            return 1
        print("chaos gate: all checks passed", file=sys.stderr)
        return 0

    if a.serve:
        base_path = (a.baseline if a.baseline != BASELINE_PATH
                     else SERVE_BASELINE_PATH)
        with open(base_path) as f:
            baseline = json.load(f)
        if a.fresh is None:
            sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
            sys.path.insert(0, REPO_ROOT)
            from benchmarks.serve_bench import serve_load
            print("running smoke serve_bench...", file=sys.stderr)
            fresh = serve_load(requests=9, rate_rps=16.0, batch=2,
                               max_new=4)
        else:
            with open(a.fresh) as f:
                fresh = json.load(f)
        failures = check_serve(baseline, fresh)
        if failures:
            print(f"serve gate: {len(failures)} check(s) failed",
                  file=sys.stderr)
            return 1
        print("serve gate: all checks passed", file=sys.stderr)
        return 0

    baseline = load_engines(a.baseline)
    if a.fresh is None:
        # self-run mode: make both the benchmarks package and the
        # src-layout repro package importable from a bare checkout
        sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
        sys.path.insert(0, REPO_ROOT)
        from benchmarks.sweep_bench import sweep_speedup
        fresh_path = os.path.join(REPO_ROOT, "BENCH_fresh.json")
        print(f"running smoke sweep_bench -> {fresh_path}", file=sys.stderr)
        fresh = sweep_speedup(pallas=True, out_path=fresh_path)["engines"]
    else:
        fresh = load_engines(a.fresh)

    failures = check(baseline, fresh, parse_engines(a.engines, a.tolerance),
                     mesh_only=a.mesh_only)
    kind = "mesh-metadata" if a.mesh_only else "bench-regression"
    if failures:
        print(f"{kind} gate: {len(failures)} engine(s) failed",
              file=sys.stderr)
        return 1
    print(f"{kind} gate: all engines within tolerance" if not a.mesh_only
          else f"{kind} gate: all rows coherent", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
