#!/usr/bin/env python
"""Doc-coverage gate for the public engine/kernel/tool APIs.

Walks the given packages (default: ``src/repro/core``,
``src/repro/kernels`` and ``tools`` — the CI gate scripts gate
themselves) with ``ast`` — no third-party dependency, so the gate runs
identically in CI and in a bare container — and fails when a module,
public class, or public function/method lacks a docstring.
Private names (leading underscore), dunders other than ``__init__``
modules, and nested ``lambda``/local helpers are exempt.

Usage::

    python tools/check_docstrings.py [path ...]

Exit status 0 when fully covered, 1 otherwise (violations listed one per
line as ``path:lineno: kind name``), mirroring pydocstyle's contract so
the CI step can swap tools later without changing semantics.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import Iterator, List, Tuple

DEFAULT_PATHS = ("src/repro/core", "src/repro/kernels", "tools")

Violation = Tuple[str, int, str, str]


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _walk_defs(tree: ast.Module) -> Iterator[Tuple[ast.AST, str]]:
    """Yield (node, kind) for every public def/class at module/class level.

    Function bodies are not descended into: local helpers are
    implementation detail, but methods of public classes are API.
    """
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _is_public(node.name):
                yield node, "function"
        elif isinstance(node, ast.ClassDef):
            if not _is_public(node.name):
                continue
            yield node, "class"
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if _is_public(sub.name):
                        yield sub, f"method {node.name}."


def check_file(path: Path) -> List[Violation]:
    """Return the docstring violations of one python file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    out: List[Violation] = []
    if ast.get_docstring(tree) is None:
        out.append((str(path), 1, "module", path.stem))
    for node, kind in _walk_defs(tree):
        if ast.get_docstring(node) is None:
            out.append((str(path), node.lineno, kind,
                        getattr(node, "name", "?")))
    return out


def main(argv: List[str]) -> int:
    """CLI entry point: check every ``.py`` under the given roots."""
    roots = [Path(p) for p in (argv or DEFAULT_PATHS)]
    violations: List[Violation] = []
    n_files = 0
    for root in roots:
        if not root.exists():
            # a typo'd/renamed path must fail loudly, not gate zero files
            print(f"error: no such path {root}", file=sys.stderr)
            return 1
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            n_files += 1
            violations.extend(check_file(f))
    for path, line, kind, name in violations:
        print(f"{path}:{line}: missing docstring on {kind}{name}"
              if kind.endswith(".") else
              f"{path}:{line}: missing docstring on {kind} {name}")
    print(f"doc-coverage: {n_files} files checked, "
          f"{len(violations)} violations", file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
